"""End-to-end driver (the paper's kind is a query engine → serving):
batched pattern-query serving through the engine, with journaling, failure
re-dispatch and straggler splitting.  Requests are submitted as query text
— the server parses, plans and caches across the whole workload.

  PYTHONPATH=src python examples/serve_queries.py
"""

from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.launch.serve import QueryServer


def main():
    # sized for single-core CPU demo; scale graph/queries up on real chips
    graph = random_labeled_graph(400, avg_degree=3.0, n_labels=8, seed=0)
    server = QueryServer(graph, batch_size=6, capacity=4096,
                         deadline_s=120.0)

    # textual queries go through the engine's parser at admission
    assert server.submit(100, "(x:L0)-/->(y:L1), (x)-//->(z:L2)")
    assert not server.submit(101, "(x:L0)-/=>(y:L1)")     # rejected: typo
    print(f"rejected q101:\n{server.rejected[101]}")
    for i in range(12):
        q = random_query_from_graph(graph, 3 + i % 2,
                                    qtype=["C", "H", "D"][i % 3], seed=i)
        server.submit(i, q)

    # one worker "dies" mid-flight: requests stay journaled
    server.step(fail=True)
    server.drain()

    done = [r for r in server.journal.values() if r.done]
    print(f"served {len(done)}/{len(server.journal)}   stats={server.stats}")
    print(f"engine caches: {server.engine.cache_info()}")
    for r in list(server.journal.values())[:8]:
        print(f"  q{r.rid}: count={r.count} backend={r.backend} "
              f"attempts={r.attempts} overflow={r.overflowed}")
    assert all(r.done for r in server.journal.values())
    assert server.stats["rejected"] == 1
    print("all requests served despite injected failure ✓")


if __name__ == "__main__":
    main()
