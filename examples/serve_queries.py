"""End-to-end driver (the paper's kind is a query engine → serving):
batched pattern-query serving with journaling, failure re-dispatch and
straggler splitting.

  PYTHONPATH=src python examples/serve_queries.py
"""

from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.launch.serve import QueryServer


def main():
    # sized for single-core CPU demo; scale graph/queries up on real chips
    graph = random_labeled_graph(400, avg_degree=3.0, n_labels=8, seed=0)
    server = QueryServer(graph, batch_size=6, capacity=4096,
                         deadline_s=120.0)

    for i in range(12):
        q = random_query_from_graph(graph, 3 + i % 2,
                                    qtype=["C", "H", "D"][i % 3], seed=i)
        server.submit(i, q)

    # one worker "dies" mid-flight: requests stay journaled
    server.step(fail=True)
    server.drain()

    done = [r for r in server.journal.values() if r.done]
    print(f"served {len(done)}/{len(server.journal)}   stats={server.stats}")
    for r in list(server.journal.values())[:8]:
        print(f"  q{r.rid}: count={r.count} attempts={r.attempts} "
              f"overflow={r.overflowed}")
    assert all(r.done for r in server.journal.values())
    print("all requests served despite injected failure ✓")


if __name__ == "__main__":
    main()
