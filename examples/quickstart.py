"""Quickstart: evaluate a hybrid graph pattern query with GM (host + device).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import CHILD, DESC, GM, GMOptions, query
from repro.core.graph import paper_example_graph
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.jaxgm import JaxGM


def main():
    # --- the paper's Fig. 1 example ---------------------------------------
    g = paper_example_graph()
    q = query(labels=[0, 1, 2, 3, 4],
              edges=[(0, 1, CHILD), (2, 1, CHILD), (0, 2, DESC),
                     (1, 3, DESC), (3, 4, DESC), (2, 4, DESC)],
              name="fig1")
    gm = GM(g)
    res = gm.match(q)
    print(f"[fig1] occurrences={res.count}  RIG nodes={res.rig_nodes} "
          f"edges={res.rig_edges}  order={res.order}")
    print(f"[fig1] first tuples (A,B,C,D,E):\n{res.tuples[:5]}")

    # --- a larger random graph: host vs device matcher --------------------
    g2 = random_labeled_graph(800, avg_degree=3.0, n_labels=8, seed=1)
    q2 = random_query_from_graph(g2, n_nodes=5, qtype="H", seed=2)
    print(f"\n[random] query: {q2}")
    host = gm2 = GM(g2).match(q2)
    print(f"[random] host GM:   count={host.count} "
          f"(match {host.matching_s * 1e3:.1f} ms, "
          f"enum {host.enumerate_s * 1e3:.1f} ms)")
    jgm = JaxGM(g2, capacity=16384, exact_sim=True)
    dev = jgm.match(q2)
    print(f"[random] device GM: count={dev.count} overflow={dev.overflowed} "
          f"|cos|={dev.fb_sizes.tolist()}")
    assert dev.count == host.count
    print("[random] host == device ✓")


if __name__ == "__main__":
    main()
