"""Quickstart: evaluate hybrid graph pattern queries through the engine.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import GM
from repro.core.graph import paper_example_graph
from repro.core.query import paper_example_query
from repro.data.graphs import random_labeled_graph
from repro.data.queries import random_query_from_graph
from repro.engine import Engine, EngineOptions


def main():
    # --- the paper's Fig. 1 example, written in the query language --------
    g = paper_example_graph()
    eng = Engine(g, label_names=["A", "B", "C", "D", "E"])
    text = ("(a:A)-/->(b:B), (c:C)-/->(b), (a)-//->(c), "
            "(b)-//->(d:D)-//->(e:E), (c)-//->(e)")
    res = eng.execute(text)
    print(f"[fig1] {text}")
    print(f"[fig1] occurrences={res.count}  RIG nodes={res.stats.rig_nodes} "
          f"edges={res.stats.rig_edges}  plan: {res.plan.explain()}")
    print(f"[fig1] first tuples (A,B,C,D,E):\n{res.tuples[:5]}")

    # textual and programmatic queries are the same thing
    assert res.count == GM(g).match(paper_example_query()).count
    print("[fig1] text query == hand-built PatternQuery ✓")

    # --- a larger random graph: the planner picks the backend -------------
    g2 = random_labeled_graph(800, avg_degree=3.0, n_labels=8, seed=1)
    eng2 = Engine(g2, options=EngineOptions(materialize=False,
                                            device_impl="reference"))
    q2 = random_query_from_graph(g2, n_nodes=5, qtype="H", seed=2)
    print(f"\n[random] query: {eng2.format(q2)}")
    print(f"[random] plan:  {eng2.explain(q2)}")
    r1 = eng2.execute(q2)
    print(f"[random] cold:  count={r1.count} backend={r1.stats.backend} "
          f"({r1.stats.total_s * 1e3:.1f} ms, label cache "
          f"{'hit' if r1.stats.label_cache_hit else 'miss'})")
    r2 = eng2.execute(q2)
    print(f"[random] warm:  count={r2.count} "
          f"({r2.stats.total_s * 1e3:.1f} ms, plan cache "
          f"{'hit' if r2.stats.plan_cache_hit else 'miss'}, label cache "
          f"{'hit' if r2.stats.label_cache_hit else 'miss'})")
    assert r1.count == r2.count == GM(g2).match(q2).count
    print(f"[random] engine == host GM ✓   caches: {eng2.cache_info()}")


if __name__ == "__main__":
    main()
